"""Training driver: ``python -m repro.launch.train --arch <id> [...]``.

Cost-model-driven by default (--autoplan): the planner enumerates sharding
plans for the requested mesh, ranks them with C(P, cc), and the winner
configures the jitted step — the paper's optimizer in the driver's seat.

On this CPU container use --reduced --mesh host for a real run; the
production meshes are exercised via dryrun.py.
"""
from __future__ import annotations

import argparse
import dataclasses
import json

import jax

from repro.configs import ARCH_IDS, SHAPES, get_config
from repro.configs.base import ShapeConfig
from repro.core.cluster import (ClusterConfig, cpu_host_config,
                                multi_pod_config, single_pod_config)
from repro.core.planner import choose_plan
from repro.core import explain as explain_mod
from repro.core.planner import build_step_program
from repro.core.costmodel import estimate
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.optim import adamw
from repro.runtime.train_loop import Trainer, TrainerConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ARCH_IDS)
    ap.add_argument("--shape", default="train_4k", choices=list(SHAPES))
    ap.add_argument("--mesh", default="host",
                    choices=["host", "single", "multi"])
    ap.add_argument("--reduced", action="store_true",
                    help="tiny same-family config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--global-batch", type=int, default=None)
    ap.add_argument("--seq-len", type=int, default=None)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--compress", default="none",
                    choices=["none", "bf16", "int8_ef"])
    ap.add_argument("--explain", action="store_true",
                    help="print the costed analytical plan and exit")
    args = ap.parse_args()

    arch = get_config(args.arch)
    if args.reduced:
        arch = arch.reduced()
        arch = dataclasses.replace(arch, dtype="float32")
    shape = SHAPES[args.shape]
    if args.global_batch or args.seq_len:
        shape = dataclasses.replace(
            shape, global_batch=args.global_batch or shape.global_batch,
            seq_len=args.seq_len or shape.seq_len)

    if args.mesh == "host":
        mesh = make_host_mesh()
        cc = cpu_host_config().with_mesh(
            tuple(mesh.devices.shape), tuple(mesh.axis_names))
    else:
        mesh = make_production_mesh(multi_pod=args.mesh == "multi")
        cc = multi_pod_config() if args.mesh == "multi" else single_pod_config()

    decisions = choose_plan(arch, shape, cc, top_k=3)
    print("== cost-based plan ranking ==")
    for d in decisions:
        print(f"  {d.plan.describe():60s} T={d.time*1e3:9.2f}ms "
              f"hbm={d.hbm_est/1e9:6.2f}GB feasible={d.feasible}")
    best = decisions[0]
    if args.explain:
        prog = build_step_program(arch, shape, best.plan, cc)
        print(explain_mod.explain(estimate(prog, cc), max_depth=3))
        return

    tcfg = TrainerConfig(steps=args.steps, ckpt_dir=args.ckpt_dir,
                         compress_scheme=args.compress,
                         log_every=max(args.steps // 10, 1))
    opt = adamw.AdamWConfig(lr=args.lr, total_steps=args.steps)
    trainer = Trainer(arch, shape, cc, mesh, plan=best.plan, opt_cfg=opt,
                      tcfg=tcfg)
    result = trainer.run(on_metrics=lambda m: print(json.dumps(m)))
    hist = result["history"]
    if hist:
        print(f"\nloss: {hist[0]['loss']:.4f} -> {hist[-1]['loss']:.4f} "
              f"over {len(hist)} logged steps")


if __name__ == "__main__":
    main()
