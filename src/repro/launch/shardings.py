"""ShardingPlan -> NamedSharding trees for params / optimizer / batch / cache.

This is where the planner's abstract decision vector becomes concrete
PartitionSpecs.  GSPMD then *generates* the collectives, and
``repro.core.hlo_cost`` costs what was generated — the paper's pipeline.

Rules are path-based with divisibility guards: an axis is only assigned to
a tensor dimension it divides; otherwise that dimension stays replicated
(never fail a compile over a sharding mismatch — fall back and let the
cost model show the replication cost).
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.planner import ShardingPlan


def _axis_size(mesh: Mesh, axes: Tuple[str, ...]) -> int:
    n = 1
    for a in axes:
        if a in mesh.shape:
            n *= mesh.shape[a]
    return n


def _guard(mesh: Mesh, dim: int, axes: Tuple[str, ...]):
    """axes if they divide dim, else None (replicated)."""
    if not axes:
        return None
    n = _axis_size(mesh, axes)
    if n <= 1 or dim % n != 0:
        return None
    return axes if len(axes) > 1 else axes[0]


def _ns(mesh: Mesh, *spec) -> NamedSharding:
    return NamedSharding(mesh, P(*spec))


def param_sharding(mesh: Mesh, plan: ShardingPlan, path: str,
                   shape: Tuple[int, ...]) -> NamedSharding:
    tp, fsdp, ep = plan.tp_axes, plan.fsdp_axes, plan.ep_axes
    nd = len(shape)
    stacked = ("blocks" in path or "cycles" in path or "enc_blocks" in path
               or "dense_blocks" in path)
    off = 1 if (stacked and nd >= 2) else 0   # leading layer-stack axis

    def spec_with(dims):  # dims: {dim_index: axes tuple}; first-come wins
        out = [None] * nd
        used: set = set()
        for di, axes in dims.items():
            axes = tuple(a for a in axes if a not in used)
            g = _guard(mesh, shape[di], axes)
            if g is not None:
                out[di] = g
                used.update(axes)
        return _ns(mesh, *out)

    leaf = path.split("/")[-1]
    is_moe = "/moe/" in path or path.endswith("w_router")

    if leaf == "embed":
        return spec_with({0: tp, 1: fsdp})
    if leaf == "lm_head":
        return spec_with({nd - 1: tp, 0: fsdp})
    if leaf == "w_router":
        return spec_with({nd - 1: ()})
    if is_moe and leaf in ("w_up", "w_gate") and nd - off == 3:
        return spec_with({off: ep, nd - 1: tp, nd - 2: fsdp})   # ep wins ties
    if is_moe and leaf == "w_down" and nd - off == 3:
        return spec_with({off: ep, nd - 2: tp, nd - 1: fsdp})
    if leaf in ("w_q", "w_k", "w_v", "w_uq", "w_ukv", "w_gate", "w_up",
                "w_in", "w_dq", "w_dkv", "proj"):
        dims = {nd - 1: tp}
        if nd - off >= 2:
            dims[nd - 2] = fsdp
        return spec_with(dims)
    if leaf in ("w_o", "w_down", "w_out"):
        dims = {nd - 2: tp} if nd - off >= 2 else {}
        dims[nd - 1] = fsdp
        return spec_with(dims)
    if leaf in ("b_q", "b_k", "b_v", "conv_w", "conv_b"):
        return spec_with({nd - 1: tp})
    if leaf in ("A_log", "D", "dt_bias") and nd - off >= 1:
        return spec_with({nd - 1: tp})
    # norm scales, small vectors: replicated
    return _ns(mesh)


def params_shardings(mesh: Mesh, plan: ShardingPlan, params_shapes: Any) -> Any:
    flat, treedef = jax.tree_util.tree_flatten_with_path(params_shapes)
    out = []
    for path, leaf in flat:
        key = "/".join(_pstr(p) for p in path)
        out.append(param_sharding(mesh, plan, key, tuple(leaf.shape)))
    return jax.tree_util.tree_unflatten(treedef, out)


def _pstr(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return f"[{p.idx}]"
    return str(p)


def batch_shardings(mesh: Mesh, plan: ShardingPlan, batch_shapes: Any) -> Any:
    b_axes = tuple(a for a in plan.batch_axes if a in mesh.shape)
    s_axes = tuple(a for a in plan.seq_axes if a in mesh.shape)

    def one(path, leaf):
        nd = len(leaf.shape)
        spec = [None] * nd
        spec[0] = _guard(mesh, leaf.shape[0], b_axes)
        if nd >= 2 and s_axes:
            spec[1] = _guard(mesh, leaf.shape[1], s_axes)
        return _ns(mesh, *spec)

    flat, treedef = jax.tree_util.tree_flatten_with_path(batch_shapes)
    return jax.tree_util.tree_unflatten(
        treedef, [one(p, l) for p, l in flat])


def cache_shardings(mesh: Mesh, plan: ShardingPlan, cache_shapes: Any) -> Any:
    """Decode caches: [L, B, H, S, D]-style — batch over data, heads over tp."""
    b_axes = tuple(a for a in plan.batch_axes if a in mesh.shape)
    tp = tuple(a for a in plan.tp_axes if a in mesh.shape)

    def one(path, leaf):
        key = "/".join(_pstr(p) for p in path)
        nd = len(leaf.shape)
        shape = leaf.shape
        if key.endswith("pos") or "kpos" in key:
            return _ns(mesh)
        if nd == 5:        # [L, B, H, S, D] kv / [L, B, H, P, N] ssm state
            bg = _guard(mesh, shape[1], b_axes)
            sg = None
            if bg is None and "state" not in key:
                # batch not shardable (e.g. long_500k B=1): shard KV length
                sg = _guard(mesh, shape[3], b_axes)
            return _ns(mesh, None, bg, _guard(mesh, shape[2], tp), sg, None)
        if nd == 4:        # [L, B, S, r] mla latent / [L, B, W, C] conv
            bg = _guard(mesh, shape[1], b_axes)
            sg = None
            if bg is None and "conv" not in key:
                sg = _guard(mesh, shape[2], b_axes)
            last = _guard(mesh, shape[3], tp) if "conv" in key else None
            return _ns(mesh, None, bg, sg, last)
        if nd >= 2:
            return _ns(mesh, None, _guard(mesh, shape[1], b_axes),
                       *([None] * (nd - 2)))
        return _ns(mesh)

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_shapes)
    return jax.tree_util.tree_unflatten(
        treedef, [one(p, l) for p, l in flat])


def opt_state_shardings(mesh: Mesh, plan: ShardingPlan, params_sh: Any,
                        opt_shapes: Any) -> Any:
    """AdamW m/v shard like params, plus ZeRO-1: when ``plan.zero1`` the
    moments additionally shard over the data axes on the first dimension
    they divide (GSPMD then reduce-scatters grads into the update and
    all-gathers the delta — optimizer state never replicates over DP)."""
    from repro.optim.adamw import AdamWState
    if not getattr(plan, "zero1", False):
        return AdamWState(step=_ns(mesh), m=params_sh, v=params_sh)
    b_axes = tuple(a for a in plan.batch_axes if a in mesh.shape)

    def zero1_spec(psh: NamedSharding, shapes) -> NamedSharding:
        spec = list(psh.spec) + [None] * (len(shapes.shape) - len(psh.spec))
        used = set()
        for entry in spec:
            if entry is None:
                continue
            used.update(entry if isinstance(entry, tuple) else (entry,))
        axes = tuple(a for a in b_axes if a not in used)
        if not axes:
            return psh
        n = _axis_size(mesh, axes)
        for i, entry in enumerate(spec):
            if entry is None and shapes.shape[i] % n == 0 and n > 1:
                spec[i] = axes if len(axes) > 1 else axes[0]
                return _ns(mesh, *spec)
        return psh

    m_sh = jax.tree.map(zero1_spec, params_sh, opt_shapes.m)
    return AdamWState(step=_ns(mesh), m=m_sh, v=m_sh)
