"""Synthetic-corpus data pipeline with async host prefetch.

Deterministic token stream (seeded per shard) standing in for a tokenized
corpus.  The pipeline overlaps host-side batch synthesis with device
compute via a background prefetch thread (the paper's IO/compute
linearization applies: the cost model charges batch staging HOST->HBM once
per step unless prefetch hides it — `overlap` plan knob).

Multi-host discipline: each process owns `global_batch / num_hosts` rows
(data-parallel shard), selected by `host_index`, so the same code runs
unchanged on a real pod slice.
"""
from __future__ import annotations

import queue
import threading
from typing import Dict, Iterator, Optional, Tuple

import jax
import numpy as np


class SyntheticLM:
    """Zipf-ish synthetic token stream with a learnable bigram structure.

    Not uniform noise: tokens follow a deterministic mixing rule so a real
    model can actually reduce loss on it (used by the e2e training example).
    """

    def __init__(self, vocab_size: int, seq_len: int, batch: int,
                 seed: int = 0, frontend_shape: Optional[Tuple[int, ...]] = None):
        self.vocab_size = vocab_size
        self.seq_len = seq_len
        self.batch = batch
        self.seed = seed
        self.frontend_shape = frontend_shape

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng(self.seed * 1_000_003 + step)
        b, s, v = self.batch, self.seq_len, self.vocab_size
        # structured stream: x_{t+1} = (a * x_t + drift) % v with noise
        x0 = rng.integers(0, v, size=(b, 1))
        a = 31
        drift = rng.integers(0, 7, size=(b, 1))
        t = np.arange(s)[None, :]
        base = (x0 * pow(a, 1, v) + drift * t) % v
        noise = rng.integers(0, v, size=(b, s))
        use_noise = rng.random((b, s)) < 0.1
        tokens = np.where(use_noise, noise, base).astype(np.int32)
        out = {"tokens": tokens}
        if self.frontend_shape is not None:
            out["frontend"] = rng.standard_normal(
                (b,) + tuple(self.frontend_shape[1:]), dtype=np.float32)
        return out


class PrefetchIterator:
    """Background-thread prefetch of host batches (+ optional device_put)."""

    def __init__(self, source: SyntheticLM, *, start_step: int = 0,
                 prefetch: int = 2, sharding=None):
        self.source = source
        self.sharding = sharding
        self._q: "queue.Queue" = queue.Queue(maxsize=prefetch)
        self._step = start_step
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self) -> None:
        step = self._step
        while not self._stop.is_set():
            batch = self.source.batch_at(step)
            if self.sharding is not None:
                batch = {k: jax.device_put(v, self.sharding.get(k))
                         if self.sharding.get(k) is not None else v
                         for k, v in batch.items()}
            try:
                self._q.put((step, batch), timeout=0.5)
                step += 1
            except queue.Full:
                continue

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        if self._stop.is_set():
            raise StopIteration
        return self._q.get()

    def close(self) -> None:
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2)


def make_pipeline(vocab_size: int, seq_len: int, global_batch: int, *,
                  host_index: int = 0, num_hosts: int = 1, seed: int = 0,
                  frontend_shape=None, prefetch: int = 2,
                  sharding=None, start_step: int = 0) -> PrefetchIterator:
    local_batch = max(global_batch // num_hosts, 1)
    src = SyntheticLM(vocab_size, seq_len, local_batch,
                      seed=seed + host_index, frontend_shape=frontend_shape)
    return PrefetchIterator(src, prefetch=prefetch, sharding=sharding,
                            start_step=start_step)
